"""Paper Table 5 (§5.6): the d=128 per-token catastrophe and its fix.

On the d=128 stand-in with an injected dominant K coordinate (the paper's
layer-0 Qwen probe finding), 4-bit per-token scaling collapses; the
recovery ladder is:
    per_token  >>  per_group(g32)  >  per_channel  >  per_channel+group(g16)
Per-channel is realized as the static lambda (one forward pass over a
calibration window, §7.1); per_channel_group is lambda + per-group --
the deployment recipe the fused kernel implements.
"""
from __future__ import annotations

import jax

from benchmarks.common import (eval_tokens, fmt_table, hook_ppl, save_record,
                               trained_standin)
from repro.core import calibrate as C
from repro.core.outliers import inject_kv_outliers
from repro.core.transforms import Rotation
from repro.models import build_model
from repro.models.lm import Rotations, slice_rotation


def _calibrated_rots(model, params, toks, rots):
    """Static per-channel lambda from one forward pass (paper §7.1)."""
    acts = model.collect_kv(params, toks)  # {layer: (k, v)} stacked
    k_act, v_act = acts  # (L, N, d) each

    def calib_one(rot_stacked, act):
        n_layers = act.shape[0]
        lams = []
        for i in range(n_layers):
            rot_i = slice_rotation(rot_stacked, i)
            lams.append(C.static_lambda(rot_i, act[i]))
        import jax.numpy as jnp
        lam = jnp.stack(lams)
        return Rotation(rot_stacked.matrix, lam, rot_stacked.signs,
                        rot_stacked.kind)

    return Rotations(k=calib_one(rots.k, k_act), v=calib_one(rots.v, v_act))


SCHEMES = [
    ("per_token", dict(scheme="per_token", group=32), False),
    ("per_group_g32", dict(scheme="per_group", group=32), False),
    ("per_channel", dict(scheme="per_channel", group=32), True),
    ("per_channel_group_g16", dict(scheme="per_channel_group", group=16), True),
    ("per_token_8bit_ref", dict(scheme="per_token", group=32, bits=8), False),
]


def run(*, model_name: str = "smol-d128", quick: bool = False) -> dict:
    cfg, model, params = trained_standin(model_name)
    # the catastrophe mechanism: one dominant K coordinate (paper probe)
    params = inject_kv_outliers(params, head_dim=cfg.head_dim, alpha=30.0,
                                inject_v=False)
    toks = eval_tokens(batch=4 if quick else 8)
    base = hook_ppl(model, params, toks, None, None)

    rots_plain = model.init_rotations(jax.random.PRNGKey(1))
    rots_cal = _calibrated_rots(model, params, toks, rots_plain)

    rows = []
    for name, kw, needs_lambda in SCHEMES:
        kw = dict(kw)
        bits = kw.pop("bits", 4)
        rots = rots_cal if needs_lambda else rots_plain
        ppl = hook_ppl(model, params, toks, rots,
                       dict(bits=bits, **kw))
        rows.append({"scheme": name, "bits": bits,
                     "dppl": round(ppl - base, 4)})
        print(f"  {name:24s} b={bits}: dPPL = {ppl - base:+.4f}")

    d = {r["scheme"]: r["dppl"] for r in rows}
    record = {
        "table": "table5", "model": model_name, "fp_ppl": base, "rows": rows,
        "claims": {
            # Table 5's robust content: per-token collapses at 4-bit; each
            # single scheme recovers part; the COMBINED per-channel +
            # per-group recipe recovers most.  The relative order of the
            # two middle rungs is activation-structure-dependent (the
            # paper's Qwen has many structured outliers; our stand-in
            # injects one channel), so it is reported but not asserted.
            "per_token_catastrophic_vs_8bit":
                d["per_token"] > 10 * max(abs(d["per_token_8bit_ref"]), 1e-3),
            "group_helps": d["per_group_g32"] < d["per_token"],
            "channel_helps": d["per_channel"] < d["per_token"],
            "combined_best": d["per_channel_group_g16"] < min(
                d["per_channel"], d["per_group_g32"], d["per_token"]),
        },
    }
    save_record("ppl_scaling_schemes", record)
    print(fmt_table(rows, ["scheme", "bits", "dppl"]))
    print("claims:", record["claims"])
    return record


if __name__ == "__main__":
    run()
