"""Paper Fig 2 / Table 1: DeltaPPL vs KV-cache bit width, per rotation.

identity / SRHT / SRFT at b in {3,4,6,8}, per-token scaling, multi-seed
(the seed draws the per-layer sign diagonals).  Expected orderings:
  * identity >> SRHT ~ SRFT at 3-4 bit (rotation spreads outliers);
  * SRHT and SRFT within seed variance of each other at every width;
  * 6/8-bit lossless for all.
Stand-in models carry an injected outlier channel (core/outliers.py) so
the 4-bit separation reflects the paper's §5.6 mechanism.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import (eval_tokens, fmt_table, hook_ppl, save_record,
                               trained_standin)
from repro.core.outliers import inject_kv_outliers
from repro.models import build_model

BITS = (3, 4, 6, 8)
ROTATIONS = ("identity", "srht", "srft")


def run(*, model_name: str = "smol-d64", seeds: int = 3,
        quick: bool = False) -> dict:
    if quick:
        seeds, bits = 1, (4, 8)
    else:
        bits = BITS
    cfg, model, params = trained_standin(model_name)
    params = inject_kv_outliers(params, head_dim=cfg.head_dim, alpha=20.0)
    toks = eval_tokens()

    base = hook_ppl(model, params, toks, None, None)
    rows = []
    for rot_kind in ROTATIONS:
        m = build_model(dataclasses.replace(cfg, rotation=rot_kind))
        for b in bits:
            dppl = []
            for s in range(seeds):
                rots = m.init_rotations(jax.random.PRNGKey(1 + s))
                ppl = hook_ppl(
                    model, params, toks, rots,
                    dict(bits=b, scheme="per_token", group=32),
                )
                dppl.append(ppl - base)
            rows.append({
                "rotation": rot_kind, "bits": b,
                "dppl_mean": round(float(np.mean(dppl)), 4),
                "dppl_std": round(float(np.std(dppl)), 4),
            })
            print(f"  {rot_kind:8s} b={b}: dPPL = "
                  f"{np.mean(dppl):+.4f} ± {np.std(dppl):.4f}")

    record = {"table": "fig2_table1", "model": model_name,
              "fp_ppl": base, "rows": rows}

    # the paper's three claims, checked mechanically
    def dppl(rot, b):
        return next(r for r in rows if r["rotation"] == rot and
                    r["bits"] == b)["dppl_mean"]
    four = min(b for b in bits if b >= 4)
    record["claims"] = {
        "identity_worst_at_4bit":
            dppl("identity", four) > max(dppl("srht", four), dppl("srft", four)),
        "srft_equals_srht_within_noise":
            abs(dppl("srft", four) - dppl("srht", four))
            < max(0.05, 3 * max(r["dppl_std"] for r in rows) + 0.02),
        # paper Fig 2: 6/8-bit lossless for BOTH ROTATIONS (identity is
        # not claimed lossless -- the injected outlier costs it ~0.03)
        "8bit_lossless": all(abs(dppl(r, 8)) < 0.02
                             for r in ("srht", "srft")),
    }
    save_record("ppl_rotations", record)
    print(fmt_table(rows, ["rotation", "bits", "dppl_mean", "dppl_std"]))
    print("claims:", record["claims"])
    return record


if __name__ == "__main__":
    run()
