"""Inject the fitted roofline table into EXPERIMENTS.md (the
<!-- ROOFLINE_TABLE --> marker).  Run after roofline_fit --all."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "roofline")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main():
    rows = []
    skipped = []
    for path in sorted(glob.glob(os.path.join(ART, "*__single.json"))):
        c = json.load(open(path))
        if c.get("status") == "skipped":
            skipped.append((c["arch"], c["shape"]))
            continue
        if c.get("status") != "ok":
            rows.append((0, c["arch"], c["shape"], "ERROR", "", "", "", ""))
            continue
        r = c["roofline"]
        mf = c["model_flops"]
        tmax = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = (mf["model_flops"] / c["chips"] / 197e12) / tmax if tmax else 0
        rows.append((
            frac, c["arch"], c["shape"],
            r["bottleneck"].replace("_s", ""),
            fmt_s(r["compute_s"]), fmt_s(r["memory_s"]),
            fmt_s(r["collective_s"]),
            f"{mf.get('useful_ratio') or 0:.3f}",
        ))
    rows.sort(key=lambda t: (t[1], t[2]))
    lines = [
        "| arch | shape | bottleneck | compute | memory | collective |"
        " useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for frac, arch, shape, b, cs, ms, xs, ur in rows:
        lines.append(
            f"| {arch} | {shape} | {b} | {cs} | {ms} | {xs} | {ur} "
            f"| {frac:.4f} |"
        )
    lines.append("")
    lines.append(
        f"(+{len(skipped)} long_500k cells recorded skipped for pure "
        "full-attention archs per DESIGN.md §3: "
        + ", ".join(a for a, _ in skipped) + ")"
    )
    table = "\n".join(lines)

    text = open(EXP).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    assert marker in text, "marker missing"
    text = text.replace(marker, marker + "\n\n" + table, 1)
    open(EXP, "w").write(text)
    print(f"injected {len(rows)} rows + {len(skipped)} skips")


if __name__ == "__main__":
    main()
