"""Paper Tables 3/4 (§5): post-training learned-rotation ablation.

Variants on collected K activations of the trained stand-in:
  random SRFT / SRFT+lambda / SRFT+Cayley+lambda / SRFT+Householder(k=d/2)
  +lambda / no-SRFT (identity base) learned R+lambda.
Reports calibration-MSE reduction AND downstream hook DeltaPPL, checking
the paper's central separation: no-SRFT wins MSE but loses PPL, and the
Householder variant matches Cayley with half the parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (eval_tokens, fmt_table, hook_ppl, save_record,
                               trained_standin)
from repro.core import calibrate as C
from repro.core.outliers import inject_kv_outliers
from repro.core.transforms import Rotation, make_rotation
from repro.models.lm import Rotations, slice_rotation


def _stack_like(rots_stacked, per_layer: list[Rotation]) -> Rotation:
    return Rotation(
        matrix=jnp.stack([r.matrix for r in per_layer]),
        lam=jnp.stack([r.lam for r in per_layer]),
        signs=jnp.stack([r.signs for r in per_layer]),
        kind=per_layer[0].kind,
    )


VARIANTS = [
    ("random_srft", "srft", {}),
    ("srft_lambda", "srft", dict(learn_lambda=True)),
    ("srft_cayley_lambda", "srft",
     dict(learn_lambda=True, learn_cayley=True)),
    ("srft_householder_lambda", "srft",
     dict(learn_lambda=True, learn_householder=-1)),  # -1 -> d//2
    ("nosrft_cayley_lambda", "identity",
     dict(learn_lambda=True, learn_cayley=True)),
]


def run(*, model_name: str = "smol-d64", steps: int = 120,
        quick: bool = False) -> dict:
    if quick:
        steps = 50
    cfg, model, params = trained_standin(model_name)
    params = inject_kv_outliers(params, head_dim=cfg.head_dim, alpha=20.0)
    d = cfg.head_dim
    toks = eval_tokens(batch=4 if quick else 8)
    base = hook_ppl(model, params, toks, None, None)

    k_act, v_act = model.collect_kv(params, toks)  # (L,B,H,S,d)
    L = k_act.shape[0]
    acts = {
        "k": k_act.reshape(L, -1, d),
        "v": v_act.reshape(L, -1, d),
    }

    rows = []
    for name, base_kind, kw in VARIANTS:
        kw = dict(kw)
        if kw.get("learn_householder") == -1:
            kw["learn_householder"] = d // 2
        per_kv = {}
        mse_red = []
        for which in ("k", "v"):
            fitted = []
            for i in range(L):
                rot0 = make_rotation(base_kind, jax.random.PRNGKey(10 + i), d)
                if kw:  # learned variants: per layer per channel (paper §5.1)
                    rot_i, diag = C.calibrate(
                        rot0, acts[which][i], bits=4, steps=steps,
                        lr=1e-2, **kw,
                    )
                    mse_red.append(diag["mse_reduction"])
                else:
                    rot_i = rot0
                fitted.append(rot_i)
            per_kv[which] = fitted
        rots = Rotations(
            k=_stack_like(None, per_kv["k"]), v=_stack_like(None, per_kv["v"])
        )
        ppl = hook_ppl(model, params, toks, rots,
                       dict(bits=4, scheme="per_channel", group=32))
        n_params = {
            "random_srft": 0,
            "srft_lambda": d,
            "srft_cayley_lambda": d * d + d,
            "srft_householder_lambda": (d // 2) * d + d,
            "nosrft_cayley_lambda": d * d + d,
        }[name]
        row = {
            "variant": name, "params_per_ch": n_params,
            "mse_reduction": round(float(jnp.mean(jnp.asarray(mse_red))), 4)
            if mse_red else None,
            "dppl": round(ppl - base, 4),
        }
        rows.append(row)
        print(f"  {name:26s} mse_red={row['mse_reduction']} "
              f"dPPL={row['dppl']:+.4f}")

    d_ = {r["variant"]: r for r in rows}
    record = {
        "table": "table3_table4", "model": model_name, "fp_ppl": base,
        "adam_steps": steps, "rows": rows,
        "claims": {
            "all_learned_beat_random": all(
                d_[v]["dppl"] < d_["random_srft"]["dppl"]
                for v in ("srft_lambda", "srft_cayley_lambda",
                          "srft_householder_lambda")
            ),
            "householder_half_params_of_cayley":
                d_["srft_householder_lambda"]["params_per_ch"]
                < 0.6 * d_["srft_cayley_lambda"]["params_per_ch"],
            # the paper's central separation (§5.3)
            "nosrft_higher_mse_reduction":
                d_["nosrft_cayley_lambda"]["mse_reduction"]
                > d_["srft_cayley_lambda"]["mse_reduction"],
            "nosrft_worse_ppl_than_best_srft":
                d_["nosrft_cayley_lambda"]["dppl"]
                > min(d_["srft_cayley_lambda"]["dppl"],
                      d_["srft_householder_lambda"]["dppl"]),
        },
    }
    save_record("calibration_ablation", record)
    print(fmt_table(rows, ["variant", "params_per_ch", "mse_reduction",
                           "dppl"]))
    print("claims:", record["claims"])
    return record


if __name__ == "__main__":
    run()
