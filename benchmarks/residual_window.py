"""Paper §8 (Residual window): W in {4, 16, 32} trade-off sweep.

The fp32 residual window holds the most recent tokens unquantized;
quantize-and-flush fires every W steps.  The paper finds W=16 optimal:
W=4 buys <=0.01x compression but ~5% latency (flushes 4x as often);
W=32 pushes the memory ratio below 3x.

We sweep W and report (a) the exact persistent+window compression ratio
at a production-like prefix, (b) flush frequency, (c) measured quality
(hook-free: cache round-trip error on the trained stand-in), confirming
W only affects WHERE the quantization boundary sits, not steady-state
quality.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, save_record, trained_standin
from repro.core import kvcache as kvc
from repro.core.transforms import make_rotation


def ratio_with_window(d: int, group: int, window: int, prefix: int) -> float:
    bf16 = 2 * prefix * d
    int4 = prefix * (d / 2 + 4 * d / group) + window * 4 * d
    return bf16 / int4


def run(*, quick: bool = False) -> dict:
    d, group, prefix = 128, 32, 4096
    rows = []
    for W in (4, 8, 16, 32, 64):
        ratio = ratio_with_window(d, group, W, prefix)
        rows.append({
            "window": W,
            "mem_ratio": round(ratio, 3),
            "flush_every": W,
            "flush_cost_rel": round(16 / W, 2),  # flushes per 16 steps
        })
    print(fmt_table(rows, ["window", "mem_ratio", "flush_every",
                           "flush_cost_rel"]))

    # steady-state quality is window-independent: round-trip error of a
    # long-run cache at different W on identical inputs
    rot = make_rotation("srft", jax.random.PRNGKey(0), d)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 64, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 64, d))
    errs = {}
    for W in (4, 16, 32):
        cache = kvc.init_cache(1, 2, 64, d, group=group, window=W)
        cache = kvc.prefill(cache, rot, rot, k, v)
        kq, vq, plen = kvc.gather_rotated(cache)
        plen = int(plen)
        kr = rot.forward(k)  # oracle rotated values
        err = float(jnp.abs(kq[..., :plen, :] - kr[..., :plen, :]).max())
        errs[W] = err
    print("  steady-state max rotated-space error per W:", errs)

    record = {
        "table": "s8_residual_window", "rows": rows,
        "quality_err_by_window": errs,
        "claims": {
            "w16_keeps_3x": next(
                r for r in rows if r["window"] == 16)["mem_ratio"] >= 3.0,
            "w32_below_w16": next(
                r for r in rows if r["window"] == 32)["mem_ratio"]
            < next(r for r in rows if r["window"] == 16)["mem_ratio"],
            "quality_window_independent":
                max(errs.values()) - min(errs.values()) < 1e-5,
        },
    }
    save_record("residual_window", record)
    print("claims:", record["claims"])
    return record


if __name__ == "__main__":
    run()
