"""Load harness for the async serving front-end (DESIGN.md §12).

Replays ONE seeded arrival trace (launch/server/trace.py -- the same
generator the CLI uses) against two servers over identical engines:

* **sync** -- ``SyncServer``: the single-threaded reference loop.
  Admission, decode dispatch and detokenize/SSE-serialize run strictly
  one after another, so every microsecond of host work extends the
  makespan.
* **pipelined** -- ``ServingPipeline``: the threaded front-end.  The
  same bucketed admission and the SAME per-token host work (shared
  ``TokenFanout``), but detokenization runs beside the device (XLA
  releases the GIL during a chunk dispatch) instead of between
  dispatches.

Both paths issue the same device work, so the sustained-req/s gap is
purely the host work the pipeline overlaps.  Each mode runs at two
detokenize costs: **light** (the smoke model's real byte-detok --
microseconds per token, far below what a production tokenizer's BPE
decode + chat-template/JSON work costs) and **heavy** (a busy-wait
stand-in of ``--detok-us`` per token, production-shaped).  The
``pipelined_server_overlaps_host_work`` claim is scored on the heavy
rows -- best-of ``--repeats`` alternating trials, pipelined sustained
req/s >= the sync loop's -- where the overlap is the dominant term
rather than thread-wakeup noise; the light rows and the
``host_work_absorbed`` delta are reported for context.  While
measuring, the harness also checks stream parity: every request's
token stream must be bit-identical between the two servers (greedy
sampling; DESIGN.md §9/§12).

Results are MERGED into ``BENCH_decode.json`` at the repo root as
``server_measured`` rows plus the claim (read-modify-write: the
e2e_decode record this file extends is preserved), and saved to
artifacts/bench/serve_load.json.  Exit status 1 if the claim fails --
CI bench-smoke runs ``--smoke`` on every PR.

Usage:
    PYTHONPATH=src python benchmarks/serve_load.py [--smoke]
        [--requests N] [--prompt-len L] [--new-tokens T]
        [--capacity C] [--arrival {poisson,bursty,closed}]
        [--rate R] [--repeats K]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/serve_load.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import fmt_table, save_record
from repro.configs.paper_models import PAPER_MODELS
from repro.launch.batch_engine import BatchEngine, Request
from repro.launch.server import (
    ServingPipeline,
    SyncServer,
    TraceRecorder,
    make_trace,
)
from repro.launch.server.pipeline import drain_stream
from repro.models import build_model

ROOT_RECORD = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_decode.json"
)


def _mk_engine(model, params, *, capacity, s_max, policy, chunk):
    return BatchEngine(model, params, capacity=capacity, s_max=s_max,
                       policy=policy, backend="gather", chunk=chunk,
                       key=jax.random.PRNGKey(7))


def _transplant(dst: BatchEngine, src: BatchEngine) -> BatchEngine:
    """Move src's compiled callables into a fresh engine so timed runs
    exclude compilation (the e2e_decode warm-pass idiom)."""
    dst._chunk_fns = src._chunk_fns
    dst._prefill_fn = src._prefill_fn
    dst._chunk_prefill_fn = src._chunk_prefill_fn
    dst._insert_fn = src._insert_fn
    dst._insert_paged_fn = src._insert_paged_fn
    dst._reset_fn = src._reset_fn
    dst._seed_fn = src._seed_fn
    dst._slice_row_fn = src._slice_row_fn
    dst._slice_axes = src._slice_axes
    return dst


def _collect_streams(fanout_streams: dict) -> dict:
    return {rid: drain_stream(q, timeout=5.0)
            for rid, q in fanout_streams.items()}


def _trial(mk, items, mode, *, capacity, host_work_s,
           prestage=False) -> dict:
    """One timed replay.  ``host_work_s`` is the per-token
    detokenize-stage cost stand-in (``TokenFanout.host_work_s``).
    ``prestage`` (closed-burst claim trials) queues every request into
    the pipeline's intake BEFORE the stage threads start, so the
    admission sweep sees the whole burst at once and forms the same
    full packed groups the sync loop does -- identical device work on
    both sides, the makespan gap is pure host-overlap."""
    eng = mk()
    if mode == "sync":
        srv = SyncServer(eng, max_group=capacity)
        srv.fanout.host_work_s = host_work_s
        makespan = srv.replay(items)
        metrics = srv.metrics
        srv.close()
    else:
        pipe = ServingPipeline(eng, max_group=capacity,
                               admit_queue=max(len(items), 8))
        pipe.fanout.host_work_s = host_work_s
        if prestage:
            t0 = time.perf_counter()
            for item in items:
                pipe.submit(item.req)
            pipe.start()
            pipe.drain(timeout=600.0)
            makespan = time.perf_counter() - t0
        else:
            pipe.start()
            makespan = pipe.replay(items)
        pipe.shutdown()
        metrics = pipe.metrics
    snap = metrics.snapshot()
    row = {
        "mode": mode,
        "detok_us_per_tok": host_work_s * 1e6,
        "sustained_req_s": len(items) / makespan,
        "makespan_s": makespan,
        "tokens": snap["tokens_streamed"],
        "ttft_p50_ms": snap["ttft_s"]["p50"] * 1e3,
        "ttft_p99_ms": snap["ttft_s"]["p99"] * 1e3,
        "itl_p50_ms": snap["itl_s"]["p50"] * 1e3,
        "itl_p99_ms": snap["itl_s"]["p99"] * 1e3,
        "completed": snap["requests_completed"],
    }
    if row["completed"] != len(items):
        raise AssertionError(
            f"{mode}: {row['completed']} of {len(items)} requests "
            f"completed"
        )
    return row


def _tracing_trial(mk, items, enabled: bool, *, capacity,
                   host_work_s) -> dict:
    """One pre-staged closed-burst replay with the flight recorder ON
    or OFF -- identical grouping and device work either way, so the
    mean-ITL delta is the recorder's hot-path cost (one perf_counter
    read + one GIL-atomic deque append per event)."""
    eng = mk()
    trace = TraceRecorder(capacity=1 << 16, enabled=enabled)
    eng.trace = trace
    pipe = ServingPipeline(eng, max_group=capacity,
                           admit_queue=max(len(items), 8), trace=trace)
    pipe.fanout.host_work_s = host_work_s
    t0 = time.perf_counter()
    for item in items:
        pipe.submit(item.req)
    pipe.start()
    pipe.drain(timeout=600.0)
    makespan = time.perf_counter() - t0
    pipe.shutdown()
    snap = pipe.metrics.snapshot()
    if snap["requests_completed"] != len(items):
        raise AssertionError(
            f"tracing={enabled}: {snap['requests_completed']} of "
            f"{len(items)} requests completed"
        )
    return {
        "mode": "tracing-on" if enabled else "tracing-off",
        "tracing": enabled,
        "itl_mean_us": snap["itl_s"]["mean"] * 1e6,
        "itl_p50_ms": snap["itl_s"]["p50"] * 1e3,
        "itl_p99_ms": snap["itl_s"]["p99"] * 1e3,
        "sustained_req_s": len(items) / makespan,
        "makespan_s": makespan,
        "tokens": snap["tokens_streamed"],
        "trace_events": len(trace),
        "trace_dropped": trace.dropped,
    }


def _tracing_parity(mk, items, capacity) -> bool:
    """Token streams must be byte-identical with the recorder on and
    off: instrumentation is host-side timing only, no device work or
    PRNG stream may move."""
    streams = {}
    for enabled in (False, True):
        eng = mk()
        trace = TraceRecorder(capacity=1 << 16, enabled=enabled)
        eng.trace = trace
        pipe = ServingPipeline(eng, max_group=capacity,
                               admit_queue=max(len(items), 8),
                               trace=trace).start()
        s = {it.req.rid: pipe.submit(it.req) for it in items}
        streams[enabled] = _collect_streams(s)
        pipe.shutdown()
    return streams[True] == streams[False]


def measure(model, params, *, capacity, s_max, policy, chunk,
            burst_items, load_items, repeats,
            detok_s) -> tuple[dict, list, bool]:
    """Alternating trials over warm engines at two detokenize costs:
    ~0 (the smoke model's microsecond byte-detok) and ``detok_s`` per
    token (production-shaped: BPE decode + chat-template/JSON work
    costs on the order of a millisecond).  The CLAIM trials replay the
    closed burst with pre-staged intake -- grouping, and so device
    work, is then deterministic and identical on both sides.  One
    open-loop pair over ``load_items`` is measured for TTFT/ITL
    context (its grouping depends on wall-clock arrival races, so no
    claim rests on it).  Returns (best claim rows keyed by
    (mode, level), context rows, streams_identical)."""
    def mk():
        return _transplant(
            _mk_engine(model, params, capacity=capacity, s_max=s_max,
                       policy=policy, chunk=chunk), warm)

    # warm pass compiles every shape the trace touches: the closed-loop
    # run covers decode chunks/insert/reset plus full packed groups,
    # then every remaining (group size, length) prefill shape an
    # open-loop arrival race can form -- a mid-trial XLA compile would
    # otherwise poison that trial with a multi-second stall
    warm = _mk_engine(model, params, capacity=capacity, s_max=s_max,
                      policy=policy, chunk=chunk)
    warm_srv = SyncServer(warm, max_group=capacity)
    for item in burst_items:
        warm_srv.submit(item.req)
    warm_srv.run_until_drained()
    warm_srv.close()
    lens = sorted({int(np.asarray(it.req.prompt).shape[-1])
                   for it in burst_items})
    rid = 1_000_000
    for plen in lens:
        for k in range(1, capacity + 1):
            group = [Request(rid + j, prompt=np.zeros(plen, np.int32),
                             max_new_tokens=1) for j in range(k)]
            rid += k
            warm.admit_packed(group)
            while warm.has_work:
                warm.step()

    best: dict = {}
    for _ in range(repeats):
        for level, work in (("light", 0.0), ("heavy", detok_s)):
            for mode in ("sync", "pipelined"):  # alternate: fair drift
                row = _trial(mk, burst_items, mode, capacity=capacity,
                             host_work_s=work, prestage=True)
                row["host_work"] = level
                row["phase"] = "throughput"
                key = (mode, level)
                if (key not in best or row["sustained_req_s"]
                        > best[key]["sustained_req_s"]):
                    best[key] = row
    context = []
    for mode in ("sync", "pipelined"):  # open-loop latency character
        row = _trial(mk, load_items, mode, capacity=capacity,
                     host_work_s=detok_s)
        row["host_work"] = "heavy"
        row["phase"] = "latency"
        context.append(row)
    # stream parity check (streams are consumed during collection, so
    # it runs outside the timed trials; closed-loop submission => the
    # admission grouping is identical on both sides)
    sync_srv = SyncServer(mk(), max_group=capacity)
    s_streams = {it.req.rid: sync_srv.submit(it.req)
                 for it in burst_items}
    sync_srv.run_until_drained()
    ref = _collect_streams(s_streams)
    sync_srv.close()
    pipe = ServingPipeline(mk(), max_group=capacity,
                           admit_queue=max(len(burst_items), 8)).start()
    p_streams = {it.req.rid: pipe.submit(it.req) for it in burst_items}
    got = _collect_streams(p_streams)
    pipe.shutdown()
    # tracing overhead (DESIGN.md §15): same burst, recorder on vs
    # off, at production-shaped host work where per-token cost is the
    # signal.  Best-of-repeats MIN mean ITL per mode: the minimum is
    # the noise-floor estimator for a fixed-work replay
    tbest: dict = {}
    for _ in range(repeats):
        for enabled in (False, True):  # alternate: fair drift
            row = _tracing_trial(mk, burst_items, enabled,
                                 capacity=capacity, host_work_s=detok_s)
            key = row["mode"]
            if key not in tbest \
                    or row["itl_mean_us"] < tbest[key]["itl_mean_us"]:
                tbest[key] = row
    tparity = _tracing_parity(mk, burst_items, capacity)
    return best, context, got == ref, tbest, tparity


def run(*, smoke: bool = False, requests: int = 32, prompt_len: int = 48,
        new_tokens: int = 24, capacity: int = 8, chunk: int = 8,
        arrival: str = "poisson", rate: float = 50.0, repeats: int = 3,
        detok_us: float = 500.0) -> dict:
    if smoke:
        requests = min(requests, 24)
        new_tokens = min(new_tokens, 16)
        repeats = min(repeats, 3)
    cfg = PAPER_MODELS["smol-d64"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = "int4-srft"
    window = getattr(model.cache_policy(policy), "window", 1)
    s_max = prompt_len + new_tokens + window
    s_max += (-s_max) % max(window, 1)

    burst_items = make_trace(requests, prompt_len=prompt_len,
                             new_tokens=new_tokens, seed=0, align=window,
                             run_len=capacity, arrival="closed")
    load_items = make_trace(requests, prompt_len=prompt_len,
                            new_tokens=new_tokens, seed=0, align=window,
                            run_len=capacity, arrival=arrival, rate=rate)
    print(f"[serve_load] {requests} requests, claim=closed burst, "
          f"context arrival={arrival} (rate={rate}/s), "
          f"capacity={capacity}, chunk={chunk}, policy={policy}, "
          f"detok={detok_us:.0f}us/tok, {repeats} alternating trials")

    best, context, parity_ok, tbest, tparity = measure(
        model, params, capacity=capacity, s_max=s_max, policy=policy,
        chunk=chunk, burst_items=burst_items, load_items=load_items,
        repeats=repeats, detok_s=detok_us * 1e-6,
    )
    rows = [best[k] for k in (("sync", "light"), ("pipelined", "light"),
                              ("sync", "heavy"), ("pipelined", "heavy"))]
    rows += context
    for row in rows:
        row.update(policy=policy,
                   arrival=("closed" if row["phase"] == "throughput"
                            else arrival),
                   requests=requests, new_tokens=new_tokens,
                   capacity=capacity)
        for k, v in list(row.items()):
            if isinstance(v, float):
                row[k] = round(v, 3)
    print(fmt_table(rows, ["phase", "mode", "host_work", "arrival",
                           "sustained_req_s", "makespan_s",
                           "ttft_p50_ms", "itl_p50_ms", "itl_p99_ms",
                           "tokens"]))

    # how much of the injected host work each server absorbed into
    # device time (seconds of detok that did NOT extend the makespan)
    sync_delta = (best[("sync", "heavy")]["makespan_s"]
                  - best[("sync", "light")]["makespan_s"])
    pipe_delta = (best[("pipelined", "heavy")]["makespan_s"]
                  - best[("pipelined", "light")]["makespan_s"])
    speedup = (best[("pipelined", "heavy")]["sustained_req_s"]
               / max(best[("sync", "heavy")]["sustained_req_s"], 1e-9))
    # tracing overhead (DESIGN.md §15): min mean-ITL per mode across
    # the alternating trials; the claim holds the recorder to <=1%
    # mean-ITL overhead (plus a 5us absolute floor -- below that the
    # delta is timer resolution, not recorder cost)
    trows = [tbest["tracing-off"], tbest["tracing-on"]]
    for row in trows:
        row.update(policy=policy, arrival="closed", requests=requests,
                   new_tokens=new_tokens, capacity=capacity)
        for k, v in list(row.items()):
            if isinstance(v, float):
                row[k] = round(v, 3)
    print(fmt_table(trows, ["mode", "itl_mean_us", "itl_p50_ms",
                            "itl_p99_ms", "sustained_req_s",
                            "makespan_s", "trace_events"]))
    itl_off = tbest["tracing-off"]["itl_mean_us"] * 1e-6
    itl_on = tbest["tracing-on"]["itl_mean_us"] * 1e-6
    overhead_pct = 100.0 * (itl_on - itl_off) / max(itl_off, 1e-12)

    claims = {
        # the tentpole claim, at production-shaped detok cost: the
        # pipelined server sustains >= the sync loop's req/s (2%
        # measurement-noise guard band; the sync loop pays every
        # detok second serially, the pipeline runs it beside the
        # device's GIL-released execute)
        "pipelined_server_overlaps_host_work":
            bool(best[("pipelined", "heavy")]["sustained_req_s"]
                 >= 0.98 * best[("sync", "heavy")]["sustained_req_s"]),
        "server_streams_bit_identical": bool(parity_ok),
        # flight recorder stays on in production: <=1% mean-ITL
        # overhead (5us absolute guard band for timer granularity)
        "tracing_overhead_bounded":
            bool(itl_on <= itl_off * 1.01 + 5e-6),
        # recorder on/off must not move a single token byte
        "tracing_streams_bit_identical": bool(tparity),
    }
    print(f"host-work makespan growth: sync +{sync_delta:.3f}s, "
          f"pipelined +{pipe_delta:.3f}s; heavy pipelined/sync "
          f"sustained req/s: {speedup:.3f}x")
    print(f"tracing mean-ITL overhead: {overhead_pct:+.2f}% "
          f"({itl_off*1e6:.1f}us -> {itl_on*1e6:.1f}us)   "
          f"claims: {claims}")

    record = {
        "server_measured": rows,
        "server_pipeline_speedup": round(speedup, 3),
        "server_host_work_absorbed_s": round(sync_delta - pipe_delta, 3),
        "tracing_measured": trows,
        "tracing_itl_overhead_pct": round(overhead_pct, 3),
        "smoke": bool(smoke),
        "claims": claims,
    }
    save_record("serve_load", record)

    # merge into the repo-root perf trajectory WITHOUT clobbering the
    # e2e_decode record this file extends
    root = {}
    if os.path.exists(ROOT_RECORD):
        with open(ROOT_RECORD) as f:
            root = json.load(f)
    root["server_measured"] = rows
    root["server_pipeline_speedup"] = round(speedup, 3)
    root["server_host_work_absorbed_s"] = round(sync_delta - pipe_delta, 3)
    root["tracing_measured"] = trows
    root["tracing_itl_overhead_pct"] = round(overhead_pct, 3)
    root.setdefault("claims", {}).update(claims)
    with open(ROOT_RECORD, "w") as f:
        json.dump(root, f, indent=2, default=float)
    print(f"[record] merged into {os.path.abspath(ROOT_RECORD)}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "closed"])
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--detok-us", type=float, default=500.0,
                    help="per-token host-work stand-in for the heavy "
                         "rows (production BPE+template cost)")
    args = ap.parse_args()
    record = run(smoke=args.smoke, requests=args.requests,
                 prompt_len=args.prompt_len, new_tokens=args.new_tokens,
                 capacity=args.capacity, chunk=args.chunk,
                 arrival=args.arrival, rate=args.rate,
                 repeats=args.repeats, detok_us=args.detok_us)
    if not all(record["claims"].values()):
        sys.exit(1)
