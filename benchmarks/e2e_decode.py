"""Paper Table 8 / Fig 1 (the central claim): int4 KV decode vs fp16.

Two components, recorded together in ``BENCH_decode.json`` at the repo
root (the per-PR perf trajectory; CI uploads it as an artifact):

1. ROOFLINE (model): the paper measures model.generate wall-clock on
   Apple M1 unified memory.  This container has no TPU, so the claim is
   validated the way DESIGN.md §1 states it: decode is HBM-bandwidth-
   bound, so per-step time is dominated by

       t_step ~ (param_bytes + kv_bytes(prefix)) / HBM_bw + kernel_overhead

   and int4 wins iff kv_bytes shrinks by more than the added kernel
   cost.  Both sides are computed from EXACT byte/FLOP counts of our
   cache layouts, per prefix length in {256..4096} (Table 8) and at 32K.

2. MEASURED (fused vs per-step): wall-clock of the fused generation
   engine (launch/engine.py: ONE dispatch for the whole decode loop,
   cache donated) against the conventional ``jit(decode_step)``-per-
   token Python loop, across policies x supported backends x prefix
   lengths, 64 decoded tokens each (the ISSUE-2 acceptance workload).
   CPU-relative numbers: what they demonstrate is the dispatch/copy
   overhead the fusion removes, not absolute latency.

3. PAGED POOL (ISSUE-4): the paged BatchEngine's shared-prefix
   workload -- batch 8, common prompt prefix -- with COW refcount
   evidence (one physical prefix copy), peak pool bytes vs the dense
   slot footprint, and the measured int4-vs-bf16 page capacity
   multiplier (>= 2.5x sequences at equal pool bytes).

4. CHUNKED PREFILL (ISSUE-5): decode-stream stall during a concurrent
   2K-token admission -- the p50/p99 inter-token gap of a live decode
   stream while a long prompt is being admitted, chunked
   (--prefill-chunk) vs monolithic.  Monolithic admission freezes the
   stream for the whole prefill (the tail-latency failure mode); the
   chunked scheduler bounds every gap by one chunk + one decode
   dispatch.  Recorded as the ``chunked_prefill_no_stall`` claim.

5. SPECULATIVE DECODE (ISSUE-7): the self-speculative engine
   (prompt-lookup draft, one fused verify dispatch, exact-match
   acceptance, truncate_rows rollback) vs plain fused decode on
   repetitive prompts, per policy x prefix x spec_k -- output asserted
   bit-identical before timing; recorded as ``spec_decode_measured``
   rows plus the ``spec_decode_faster`` / ``spec_decode_bit_identical``
   claims.

See benchmarks/README.md for the full BENCH_decode.json schema.

Usage:
    PYTHONPATH=src python benchmarks/e2e_decode.py [--smoke] [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/e2e_decode.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (fmt_table, save_record, time_fn,
                               trained_standin)
from repro.launch.mesh import HW

ROOT_RECORD = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_decode.json"
)


def decode_step_model(*, n_layers: int, n_kv: int, d: int, batch: int,
                      prefix: int, group: int, param_bytes: float,
                      window: int = 16) -> dict:
    """Roofline time (s) of one decode step, bf16 vs int4 cache."""
    kv_bf16 = 2 * 2 * n_layers * n_kv * prefix * d * batch
    kv_int4 = 2 * n_layers * n_kv * batch * (
        prefix * (d / 2 + 4 * d / group) + window * 4 * d
    )
    t_bf16 = (param_bytes + kv_bf16) / HW.HBM_BW
    # int4 kernel overhead per step: rotate new K/V (2 d^2 matmul) per
    # layer/head/batch + dequant-in-kernel is part of the attention read
    # (already counted in kv_int4 bytes); query-fold adds one d^2 matmul.
    kernel_flops = 3 * 2.0 * d * d * n_layers * n_kv * batch
    t_int4 = (param_bytes + kv_int4) / HW.HBM_BW \
        + kernel_flops / HW.PEAK_BF16_FLOPS
    return {
        "t_bf16_us": 1e6 * t_bf16, "t_int4_us": 1e6 * t_int4,
        "delta_pct": 100.0 * (t_int4 - t_bf16) / t_bf16,
        "kv_ratio": kv_bf16 / kv_int4,
    }


# Table-8 analogue: a 1.5B-class dense model (Qwen2.5-1.5B-like: 28L,
# d=128, kv=2) and a 1B-class MQA model (Gemma-3-1B-like: 26L, d=256,
# kv=1), single chip, batch 1 -- the paper's laptop regime mapped to one
# v5e chip.
MODELS = [
    ("qwen2.5-1.5b-like", dict(n_layers=28, n_kv=2, d=128, group=32,
                               param_bytes=3.1e9)),
    ("gemma-3-1b-like", dict(n_layers=26, n_kv=1, d=256, group=32,
                             param_bytes=2.0e9)),
]


def roofline_rows() -> list[dict]:
    rows = []
    for name, kw in MODELS:
        for prefix in (256, 1024, 2048, 4096, 8192, 32768):
            r = decode_step_model(batch=1, prefix=prefix, **kw)
            rows.append({
                "model": name, "prefix": prefix,
                "bf16_us": round(r["t_bf16_us"], 1),
                "int4_us": round(r["t_int4_us"], 1),
                "delta_pct": round(r["delta_pct"], 2),
                "kv_ratio": round(r["kv_ratio"], 2),
            })
    return rows


# ---------------------------------------------------------------------------
# Measured: fused engine vs per-step loop (the ISSUE-2 workload)
# ---------------------------------------------------------------------------

def _copy_tree(tree):
    return jax.tree.map(jnp.copy, tree)


def _time_with_fresh_cache(cache0, call, iters: int) -> float:
    """Best-of-N seconds of call(cache); a fresh buffer copy per call so
    donation never consumes the template (copies made outside the timed
    region)."""
    ts = []
    for _ in range(iters + 1):  # first call compiles; dropped below
        c = _copy_tree(cache0)
        jax.block_until_ready(c)
        t0 = time.perf_counter()
        jax.block_until_ready(call(c))
        ts.append(time.perf_counter() - t0)
    return float(min(ts[1:]))


def measure_fused_vs_per_step(*, smoke: bool) -> list[dict]:
    """ms/tok of fused scan decode vs jit(decode_step)-per-token, across
    policies x supported backends x prefix lengths, 64 new tokens."""
    from repro.core.cache_api import AttendBackend, available_policies
    from repro.configs.paper_models import PAPER_MODELS
    from repro.launch.engine import Engine
    from repro.models import build_model

    cfg = PAPER_MODELS["smol-d64"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_new = 64
    batch = 1
    iters = 3
    prefixes = (16, 48) if smoke else (64, 256)
    kv_block = 64
    backends = {AttendBackend.GATHER, AttendBackend.BLOCKWISE}
    if not smoke:  # interpret-mode Pallas: slow to compile, full runs only
        backends.add(AttendBackend.KERNEL)

    rows = []
    for pname in available_policies():
        pol = model.cache_policy(pname)
        for backend in pol.supported_backends:
            if backend not in backends:
                continue
            engine = Engine(model, backend=backend, kv_block=kv_block)
            for prefix in prefixes:
                window = getattr(pol, "window", 1)
                s_max = prefix + n_new + window
                s_max += (-s_max) % kv_block  # kernel path: S % blk == 0
                prompt = jax.random.randint(
                    jax.random.PRNGKey(1), (batch, prefix), 0,
                    cfg.vocab_size,
                )
                cache = model.init_cache(batch, s_max, policy=pol,
                                         key=jax.random.PRNGKey(7))
                logits, cache0 = jax.jit(model.prefill)(params, prompt,
                                                        cache)
                tok0 = jnp.argmax(logits[:, -1], -1)[:, None].astype(
                    jnp.int32
                )

                step = jax.jit(
                    lambda p, t, c: model.decode_step(
                        p, t, c, backend=backend, kv_block=kv_block
                    )
                )

                def per_step(c):
                    tok = tok0
                    for _ in range(n_new):
                        logits, c = step(params, tok, c)
                        # host-side argmax each token, as the pre-engine
                        # serving loop did (the round-trip being measured)
                        tok = jnp.argmax(logits[:, -1], -1)[:, None] \
                            .astype(jnp.int32)
                    return tok

                def fused(c):
                    toks, _ = engine.decode(params, tok0, c, n_new)
                    return toks

                t_loop = _time_with_fresh_cache(cache0, per_step, iters)
                t_fused = _time_with_fresh_cache(cache0, fused, iters)
                rows.append({
                    "policy": pname, "backend": backend.value,
                    "prefix": prefix, "n_new": n_new,
                    "per_step_ms_tok": round(t_loop * 1e3 / n_new, 3),
                    "fused_ms_tok": round(t_fused * 1e3 / n_new, 3),
                    "speedup": round(t_loop / t_fused, 2),
                })
                print(f"  {pname:15s} {backend.value:9s} prefix={prefix:4d}: "
                      f"per-step {rows[-1]['per_step_ms_tok']:7.2f} ms/tok  "
                      f"fused {rows[-1]['fused_ms_tok']:7.2f} ms/tok  "
                      f"({rows[-1]['speedup']:.2f}x)")
    return rows


def measure_batched_throughput(*, smoke: bool) -> list[dict]:
    """Continuous batching (ISSUE-3 acceptance): decode tok/s vs batch
    size x policy through the BatchEngine's ragged slot cache.  Each
    batch size serves 2x capacity requests with MIXED prompt lengths
    (slot reuse on the critical path).  tok/s = generated tokens over
    the wall-clock of the whole serve -- admission prefills included
    (that IS the serving cost), compiles excluded via a warm pass --
    so rows show how one fused ragged dispatch amortizes across live
    requests.
    """
    from repro.configs.paper_models import PAPER_MODELS
    from repro.launch.batch_engine import BatchEngine, Request
    from repro.models import build_model

    cfg = PAPER_MODELS["smol-d64"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_new = 16 if smoke else 32
    prompts = (8, 16) if smoke else (16, 32, 48)
    s_max = max(prompts) + n_new + 16
    s_max += (-s_max) % 64  # kernel grid: S % blk == 0
    kv_block = 64
    policies = ["bf16", "int4-srft"] if smoke else \
        ["bf16", "int4-srft", "int8-per-token"]

    rows = []
    for pname in policies:
        pol = model.cache_policy(pname)
        for batch in (1, 4, 8):
            reqs = [
                Request(rid=i,
                        prompt=np.asarray(jax.random.randint(
                            jax.random.PRNGKey(50 + i),
                            (prompts[i % len(prompts)],), 0,
                            cfg.vocab_size)),
                        max_new_tokens=n_new)
                for i in range(2 * batch)
            ]

            def mk():
                return BatchEngine(
                    model, params, capacity=batch, s_max=s_max,
                    policy=pol, backend="gather", kv_block=kv_block,
                    chunk=8, key=jax.random.PRNGKey(7),
                )

            # warm pass: run the identical workload once so every jit
            # (chunk sizes, prefill shapes, insert/reset) is compiled;
            # transplant the compiled callables into a fresh engine for
            # the timed pass
            warm = mk()
            for _ in warm.run(list(reqs)):
                pass
            engine = mk()
            engine._chunk_fns = warm._chunk_fns
            engine._prefill_fn = warm._prefill_fn
            engine._insert_fn = warm._insert_fn
            engine._reset_fn = warm._reset_fn

            t0 = time.perf_counter()
            n_tok = 0
            for comp in engine.run(list(reqs)):
                n_tok += len(comp.tokens)
            t = time.perf_counter() - t0
            rows.append({
                "policy": pname, "batch": batch,
                "requests": len(reqs), "n_new": n_new,
                "tok_s": round(n_tok / t, 1),
                "ms_tok": round(t * 1e3 / n_tok, 3),
            })
            print(f"  {pname:15s} batch={batch}: {rows[-1]['tok_s']:8.1f} "
                  f"tok/s  ({rows[-1]['ms_tok']:.2f} ms/tok, "
                  f"{len(reqs)} ragged requests)")
    return rows


def measure_paged_pool(*, smoke: bool) -> tuple[list[dict], dict]:
    """Paged KV pool (ISSUE-4 acceptance): a shared-prefix workload (8
    requests with a common prompt prefix, batch 8) served through the
    paged BatchEngine, per policy.  Records peak pool bytes vs the dense
    slot-cache bytes the same workload would have pinned, the measured
    COW sharing (one physical copy of the prefix pages, asserted via
    refcounts), and the int4-vs-bf16 page capacity multiplier (tokens
    per pool byte) -- the "3x compression => 3x resident sequences"
    claim as measured array bytes, not a slogan.
    """
    from repro.configs.paper_models import PAPER_MODELS
    from repro.launch.batch_engine import BatchEngine, Request
    from repro.models import build_model

    cfg = PAPER_MODELS["smol-d64"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    page_size = 16
    # the acceptance workload runs at full size even in smoke (~35 s on
    # a CI box): 8 requests sharing a common 512-token prompt prefix
    prefix_len = 512
    n_new = 8 if smoke else 16
    capacity = 8
    s_max = prefix_len + 8 + n_new
    s_max += (-s_max) % page_size
    prefix = np.asarray(jax.random.randint(
        jax.random.PRNGKey(90), (prefix_len,), 0, cfg.vocab_size))
    # 8 requests: common prefix + one distinct continuation token each
    reqs = [
        Request(rid=i,
                prompt=np.concatenate(
                    [prefix, np.asarray([i + 1])]).astype(np.int32),
                max_new_tokens=n_new)
        for i in range(capacity)
    ]
    policies = ["bf16", "int4-srft", "int8-per-token"]

    rows = []
    per_tok_bytes = {}
    one_copy = True
    for pname in policies:
        engine = BatchEngine(
            model, params, capacity=capacity, s_max=s_max,
            policy=pname, backend="gather", kv_block=64, chunk=2,
            key=jax.random.PRNGKey(7), paged=True, page_size=page_size,
        )
        for r in reqs:
            engine.submit(r)
        engine.step()  # admit all 8 + one short chunk: sharing is live here
        stats = engine.pool_stats()
        rc = engine._refcount_host
        n_prefix_pages = prefix_len // page_size
        # ONE physical copy: every full prefix page is mapped once and
        # referenced by all 8 rows
        shared_full = int((rc == capacity).sum())
        one_copy &= shared_full == n_prefix_pages
        pages_no_sharing = capacity * engine._pages_needed(
            prefix_len + 1, n_new)
        while engine.pending or engine.n_active:
            engine.step()
        # peak/preemptions must come from AFTER the drain (later steps
        # may preempt on an undersized pool); the live-sharing fields
        # above had to be snapshotted while rows were resident
        final = engine.pool_stats()
        page_bytes = stats["pool_bytes"] / engine.n_pages
        per_tok_bytes[pname] = page_bytes / page_size
        rows.append({
            "policy": pname, "page_size": page_size,
            "prefix_len": prefix_len, "requests": capacity,
            "prefix_pages_shared": shared_full,
            "pages_with_sharing": stats["pages_used"],
            "pages_without_sharing": pages_no_sharing,
            "peak_pool_bytes": int(final["peak_pages"] * page_bytes),
            "dense_slot_bytes": stats["dense_equiv_bytes"],
            "pool_bytes_per_token": round(per_tok_bytes[pname], 1),
            "preemptions": final["preemptions"],
        })
        print(f"  {pname:15s} prefix={prefix_len}: "
              f"{stats['pages_used']} pages w/ sharing vs "
              f"{pages_no_sharing} without ({shared_full} prefix pages "
              f"refcount={capacity}), peak "
              f"{rows[-1]['peak_pool_bytes']/1e3:.0f} KB vs dense "
              f"{rows[-1]['dense_slot_bytes']/1e3:.0f} KB")
    # capacity multiplier: sequences of equal length that fit in equal
    # pool bytes scale inversely with per-token page bytes
    int4_multiplier = per_tok_bytes["bf16"] / per_tok_bytes["int4-srft"]
    print(f"  int4 pages fit {int4_multiplier:.2f}x the sequences of "
          f"bf16 pages at equal pool bytes")
    claims = {
        # every policy's shared-prefix run holds ONE physical prefix
        # copy and beats both the no-sharing page count and the dense
        # slot footprint
        "paged_capacity_scales": bool(
            one_copy
            and all(r["pages_with_sharing"] < r["pages_without_sharing"]
                    for r in rows)
            and all(r["peak_pool_bytes"] < r["dense_slot_bytes"]
                    for r in rows)
        ),
        "int4_page_capacity_2p5x": bool(int4_multiplier >= 2.5),
    }
    return rows, {**claims,
                  "int4_page_capacity_multiplier": round(int4_multiplier, 2)}


def measure_chunked_prefill(*, smoke: bool) -> tuple[list[dict], dict]:
    """Decode-stream stall under a concurrent long-prompt admission
    (ISSUE-5 acceptance): one live stream decodes while a 2K-token
    prompt is admitted; we record the stream's inter-token gaps (wall
    clock between its tokens, a gap per token) across the admission
    window, monolithic vs chunked prefill.  The claim is the
    tail-latency inversion: chunked p99 < monolithic p99 (monolithic
    pays the whole prefill inside one gap; chunked bounds every gap by
    one chunk dispatch + one decode chunk)."""
    from repro.configs.paper_models import PAPER_MODELS
    from repro.launch.batch_engine import BatchEngine, Request
    from repro.models import build_model

    cfg = PAPER_MODELS["smol-d64"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    page_size = 16
    prompt_len = 2048  # the acceptance workload: a 2K+-token admission
    chunk_prefill = 256
    victim_new = 24 if smoke else 48  # decode budget spanning the admission
    s_max = prompt_len + 64
    victim_prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(70), (16,), 0, cfg.vocab_size))
    long_prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(71), (prompt_len,), 0, cfg.vocab_size))

    def serve(prefill_chunk):
        def mk():
            return BatchEngine(
                model, params, capacity=2, s_max=s_max, policy="int4-srft",
                backend="gather", kv_block=64, chunk=2,
                key=jax.random.PRNGKey(7), paged=True, page_size=page_size,
                prefill_chunk=prefill_chunk,
            )

        def workload(eng):
            eng.submit(Request(rid=0, prompt=victim_prompt,
                               max_new_tokens=victim_new))
            eng.step()  # victim live before the long arrival
            eng.submit(Request(rid=1, prompt=long_prompt,
                               max_new_tokens=4))
            gaps = []
            last = time.perf_counter()
            while eng.pending or eng.n_active:
                events, _ = eng.step()
                now = time.perf_counter()
                got = sum(len(t) for r, t in events if r == 0)
                if got:
                    gaps.extend([(now - last) / got] * got)
                    last = now
            return gaps

        warm = mk()  # compile everything off the clock
        workload(warm)
        eng = mk()
        eng._chunk_fns = warm._chunk_fns
        eng._prefill_fn = warm._prefill_fn
        eng._chunk_prefill_fn = warm._chunk_prefill_fn
        eng._insert_fn = warm._insert_fn
        eng._insert_paged_fn = warm._insert_paged_fn
        eng._seed_fn = warm._seed_fn
        eng._reset_fn = warm._reset_fn
        gaps = workload(eng)
        return np.asarray(gaps), eng

    rows = []
    stats = {}
    for mode, pc in (("monolithic", None), ("chunked", chunk_prefill)):
        gaps, eng = serve(pc)
        row = {
            "mode": mode, "prefill_chunk": pc, "prompt_len": prompt_len,
            "victim_tokens": int(gaps.size),
            "p50_gap_ms": round(float(np.percentile(gaps, 50)) * 1e3, 2),
            "p99_gap_ms": round(float(np.percentile(gaps, 99)) * 1e3, 2),
            "max_gap_ms": round(float(gaps.max()) * 1e3, 2),
            "prefill_chunks": eng.n_prefill_chunks,
        }
        rows.append(row)
        stats[mode] = row
        print(f"  {mode:10s}: p50 {row['p50_gap_ms']:8.2f} ms  "
              f"p99 {row['p99_gap_ms']:8.2f} ms  "
              f"max {row['max_gap_ms']:8.2f} ms  "
              f"({row['victim_tokens']} victim tokens)")
    improvement = stats["monolithic"]["p99_gap_ms"] \
        / max(stats["chunked"]["p99_gap_ms"], 1e-9)
    print(f"  chunked admission cuts the victim stream's p99 inter-token "
          f"gap {improvement:.1f}x")
    claims = {
        "chunked_prefill_no_stall": bool(
            stats["chunked"]["p99_gap_ms"] < stats["monolithic"]["p99_gap_ms"]
        ),
    }
    return rows, {**claims, "chunked_p99_improvement": round(improvement, 2)}


def measure_prefix_offload(*, smoke: bool) -> tuple[list[dict], dict]:
    """Host-RAM prefix offload (ISSUE-8 acceptance, DESIGN.md §14):
    time-to-first-token of re-admitting an evicted shared prefix,
    host-tier restore (memcpy + short tail prefill) vs full re-prefill,
    at 512- and 2048-token shared prefixes.  The restored stream is
    asserted bit-identical to the never-evicted path (a device-tier COW
    hit on a resident donor) BEFORE any timing is recorded -- the §14
    invariant the tier exists to preserve.  The tier-depth row records
    how many more prefix pages one host byte budget holds under int4
    than bf16 (the paper's compression win as cache depth)."""
    from repro.configs.paper_models import PAPER_MODELS
    from repro.launch.batch_engine import BatchEngine, Request
    from repro.models import build_model

    cfg = PAPER_MODELS["smol-d64"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    page_size = 16
    chunk_prefill = 256
    new_tokens = 4

    def mk(s_max, *, offload, policy="int4-srft"):
        kw = {"offload_bytes": 1 << 28} if offload else {}
        return BatchEngine(
            model, params, capacity=2, s_max=s_max, policy=policy,
            backend="gather", kv_block=64, chunk=2,
            key=jax.random.PRNGKey(7), paged=True, page_size=page_size,
            prefill_chunk=chunk_prefill, **kw,
        )

    def transplant(dst, src):
        for attr in ("_chunk_fns", "_prefill_fn", "_chunk_prefill_fn",
                     "_insert_fn", "_insert_paged_fn", "_seed_fn",
                     "_import_fn", "_raw_view_fn", "_reset_fn"):
            setattr(dst, attr, getattr(src, attr))
        return dst

    def admit_and_time(eng, req):
        """(seconds to req's first streamed token, completions)."""
        comps = {}
        t0 = time.perf_counter()
        eng.submit(req)
        t_first = None
        while eng.has_work:
            events, cs = eng.step()
            if t_first is None and any(r == req.rid and len(t)
                                       for r, t in events):
                t_first = time.perf_counter()
            for c in cs:
                comps[c.rid] = c
        return t_first - t0, comps

    rows = []
    stats = {}
    for prefix in (512, 2048):
        s_max = prefix + 64
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(80), (prefix + 8,), 0, cfg.vocab_size))

        def r(rid):
            return Request(rid=rid, prompt=prompt,
                           max_new_tokens=new_tokens)

        # never-evicted reference (donor resident -> device COW hit);
        # doubles as the compile warm-up for the shared dispatch shapes
        ref = mk(s_max, offload=False)
        ref_out = {c.rid: c for c in ref.run([r(0), r(1)])}
        assert ref.n_reuse_hits_device >= 1
        ref_toks = list(ref_out[1].tokens)

        # warm the restore-path shapes (import jit specializes per
        # restored-page count) off the clock
        warm = transplant(mk(s_max, offload=True), ref)
        _ = {c.rid: c for c in warm.run([r(0)])}
        admit_and_time(warm, r(1))
        assert warm.n_reuse_hits_host == 1

        # timed: evict -> host restore
        off = transplant(mk(s_max, offload=True), warm)
        _ = {c.rid: c for c in off.run([r(0)])}
        restore_s, comps = admit_and_time(off, r(1))
        assert off.n_reuse_hits_host == 1
        bit = list(comps[1].tokens) == ref_toks

        # timed: evict -> full re-prefill (no host tier: free-time
        # pruning forgot the prefix, exactly pre-PR behavior)
        pre = transplant(mk(s_max, offload=False), warm)
        _ = {c.rid: c for c in pre.run([r(0)])}
        reprefill_s, _ = admit_and_time(pre, r(1))
        assert pre.n_reuse_hits_host == 0

        row = {
            "policy": "int4-srft", "prefix": prefix,
            "restore_ttft_ms": round(restore_s * 1e3, 2),
            "reprefill_ttft_ms": round(reprefill_s * 1e3, 2),
            "restore_speedup": round(reprefill_s / max(restore_s, 1e-9),
                                     2),
            "restored_tokens": int(off.n_restored_tokens),
            "spilled_pages": int(off.n_spilled_pages),
            "bit_identical": bool(bit),
        }
        rows.append(row)
        stats[prefix] = row
        print(f"  prefix {prefix:5d}: restore TTFT "
              f"{row['restore_ttft_ms']:8.2f} ms vs re-prefill "
              f"{row['reprefill_ttft_ms']:8.2f} ms "
              f"({row['restore_speedup']:.1f}x, "
              f"{row['restored_tokens']} tokens memcpy'd, "
              f"bit-identical={row['bit_identical']})")

    # tier depth: pages one host byte budget holds, int4 vs bf16 --
    # measured from actual exported page payload bytes (40-token donor
    # -> 2 spilled pages per policy; per-page bytes are prefix-free)
    page_bytes = {}
    for policy in ("int4-srft", "bf16"):
        eng = mk(64, offload=True, policy=policy)
        p40 = np.asarray(jax.random.randint(
            jax.random.PRNGKey(81), (40,), 0, cfg.vocab_size))
        for _ in eng.run([Request(rid=0, prompt=p40, max_new_tokens=4)]):
            pass
        s = eng.prefix_store.stats()
        page_bytes[policy] = s["ram_bytes"] / max(s["puts"], 1)
    depth = page_bytes["bf16"] / page_bytes["int4-srft"]
    rows.append({
        "policy": "tier-depth", "prefix": 0,
        "int4_page_bytes": int(page_bytes["int4-srft"]),
        "bf16_page_bytes": int(page_bytes["bf16"]),
        "tier_depth_ratio": round(depth, 2),
    })
    print(f"  host-tier depth: int4 pages are {depth:.2f}x smaller -- "
          f"one byte budget holds {depth:.2f}x the prefix tokens")

    claims = {
        "offload_bit_identical": all(
            r["bit_identical"] for r in rows if "bit_identical" in r),
        # the acceptance workload: restore beats re-prefill on the
        # 2048-token shared prefix
        "offload_restore_faster_than_prefill": bool(
            stats[2048]["restore_speedup"] > 1.0),
    }
    return rows, {
        **claims,
        "offload_restore_speedup": stats[2048]["restore_speedup"],
        "offload_tier_depth_ratio": round(depth, 2),
    }


def measure_spec_decode(*, smoke: bool) -> tuple[list[dict], dict]:
    """Self-speculative decode (ISSUE-7 acceptance, DESIGN.md §13):
    end-to-end ms/tok of the fused draft-verify-rollback engine vs plain
    fused decode -- same weights, same prefilled cache, greedy, 64 new
    tokens -- with the output asserted bit-identical per row BEFORE any
    timing is recorded (the whole point of exact-match acceptance).

    Prompts are repetitive (an 8-token base, tiled): prompt-lookup
    drafting pays off exactly when continuations echo history (code,
    templated text, retrieval dumps); a random prompt would pin
    acceptance near zero and measure only verify overhead.  The recorded
    acceptance_rate column shows what the win rides on.  The claim is
    spec ms/tok <= plain ms/tok on at least one policy x prefix cell
    (CPU-relative, like every measured table here)."""
    from repro.core.cache_api import AttendBackend, available_policies
    from repro.configs.paper_models import PAPER_MODELS
    from repro.launch.engine import Engine
    from repro.models import build_model

    cfg = PAPER_MODELS["smol-d64"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, backend=AttendBackend.GATHER, kv_block=64)
    n_new = 64
    iters = 3
    prefixes = (64, 256)
    ks = (4,) if smoke else (4, 8)
    policies = ["bf16", "int4-srft"] if smoke else \
        list(available_policies())

    rows = []
    for pname in policies:
        pol = model.cache_policy(pname)
        window = getattr(pol, "window", None)
        for prefix in prefixes:
            for spec_k in ks:
                if window and spec_k > window:
                    continue
                base = jax.random.randint(
                    jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
                prompt = jnp.tile(base, (1, -(-prefix // 8)))[:, :prefix]
                s_max = prefix + n_new + spec_k + (window or 1)
                s_max += (-s_max) % 64
                cache = model.init_cache(1, s_max, policy=pol,
                                         key=jax.random.PRNGKey(7))
                logits, cache0 = jax.jit(model.prefill)(params, prompt,
                                                        cache)
                tok0 = jnp.argmax(logits[:, -1], -1)[:, None].astype(
                    jnp.int32)

                def plain(c):
                    toks, _ = engine.decode(params, tok0, c, n_new)
                    return toks

                def spec(c):
                    toks, _, _ = engine.decode_spec(
                        params, tok0, c, n_new, prompt=prompt,
                        spec_k=spec_k)
                    return toks

                # bit-identity first: a speedup on diverged output
                # would be meaningless
                ref = plain(_copy_tree(cache0))
                got, _, stats = engine.decode_spec(
                    params, tok0, _copy_tree(cache0), n_new,
                    prompt=prompt, spec_k=spec_k)
                identical = bool(jnp.array_equal(ref, got))
                drafted = int(stats["drafted"])
                accepted = int(stats["accepted"])

                t_plain = _time_with_fresh_cache(cache0, plain, iters)
                t_spec = _time_with_fresh_cache(cache0, spec, iters)
                rows.append({
                    "policy": pname, "prefix": prefix,
                    "spec_k": spec_k, "n_new": n_new,
                    "plain_ms_tok": round(t_plain * 1e3 / n_new, 3),
                    "spec_ms_tok": round(t_spec * 1e3 / n_new, 3),
                    "speedup": round(t_plain / t_spec, 2),
                    "acceptance_rate": round(
                        accepted / max(drafted, 1), 3),
                    "drafted": drafted, "accepted": accepted,
                    "bit_identical": identical,
                })
                print(f"  {pname:15s} prefix={prefix:4d} k={spec_k}: "
                      f"plain {rows[-1]['plain_ms_tok']:7.3f} ms/tok  "
                      f"spec {rows[-1]['spec_ms_tok']:7.3f} ms/tok  "
                      f"({rows[-1]['speedup']:.2f}x, "
                      f"acc={rows[-1]['acceptance_rate']:.2f}, "
                      f"identical={identical})")
    claims = {
        "spec_decode_bit_identical": all(r["bit_identical"]
                                         for r in rows),
        # the tentpole acceptance: spec ms/tok <= plain on at least one
        # policy x prefix cell (per-cell wins recorded for inspection)
        "spec_decode_faster": any(
            r["spec_ms_tok"] <= r["plain_ms_tok"] for r in rows),
    }
    best = max(r["speedup"] for r in rows)
    print(f"  best spec-decode speedup: {best:.2f}x "
          f"(wins {sum(r['spec_ms_tok'] <= r['plain_ms_tok'] for r in rows)}"
          f"/{len(rows)} cells, all bit-identical="
          f"{claims['spec_decode_bit_identical']})")
    return rows, {**claims, "spec_best_speedup": round(best, 2)}


def run(*, quick: bool = False, smoke: bool = False) -> dict:
    rows = roofline_rows()
    print(fmt_table(rows, ["model", "prefix", "bf16_us", "int4_us",
                           "delta_pct", "kv_ratio"]))

    print("\nmeasured: fused scan decode (donated cache) vs per-step loop")
    engine_rows = measure_fused_vs_per_step(smoke=smoke or quick)

    print("\nmeasured: continuous batching (ragged slot cache) tok/s "
          "vs batch size")
    batched_rows = measure_batched_throughput(smoke=smoke or quick)

    print("\nmeasured: paged KV pool (batch 8, shared-prefix workload, "
          "COW refcounts + byte accounting)")
    paged_rows, paged_claims = measure_paged_pool(smoke=smoke or quick)

    print("\nmeasured: chunked prefill (decode-stream stall during a "
          "concurrent 2K-token admission)")
    chunked_rows, chunked_claims = measure_chunked_prefill(
        smoke=smoke or quick)

    print("\nmeasured: self-speculative decode (prompt-lookup draft + "
          "fused verify, bit-identical greedy)")
    spec_rows, spec_claims = measure_spec_decode(smoke=smoke or quick)

    print("\nmeasured: host-RAM prefix offload (evict -> restore TTFT "
          "vs full re-prefill, bit-identity asserted first)")
    offload_rows, offload_claims = measure_prefix_offload(
        smoke=smoke or quick)

    # ISSUE-2 acceptance: fused 64-token decode improves on the per-step
    # loop.  Claimed on the geometric-mean speedup (single rows can lose
    # to scheduler noise on a loaded CI box; per-row wins are recorded in
    # engine_measured for inspection).
    speedups = [r["per_step_ms_tok"] / r["fused_ms_tok"]
                for r in engine_rows]
    geomean = float(np.exp(np.mean(np.log(speedups))))
    print(f"  fused-vs-per-step geomean speedup: {geomean:.2f}x "
          f"(wins {sum(s > 1 for s in speedups)}/{len(speedups)} rows)")
    # ISSUE-3 acceptance: ragged batched decode throughput grows with
    # batch size (per policy, batch 8 vs batch 1)
    def _tok_s(pname, batch):
        return next(r["tok_s"] for r in batched_rows
                    if r["policy"] == pname and r["batch"] == batch)

    batch_scaling = all(
        _tok_s(p, 8) > _tok_s(p, 1)
        for p in {r["policy"] for r in batched_rows}
    )
    claims = {
        # the paper's inversion: negative delta at every tested prefix
        "int4_faster_at_all_prefixes_tpu_model": all(
            r["delta_pct"] < 0 for r in rows),
        "advantage_grows_with_prefix": all(
            max(r["delta_pct"] for r in rows if r["model"] == name
                and r["prefix"] == 32768)
            < min(r["delta_pct"] for r in rows if r["model"] == name
                  and r["prefix"] == 256)
            for name, _ in MODELS),
        "fused_beats_per_step_64tok": geomean > 1.0,
        "batched_throughput_scales": batch_scaling,
        # ISSUE-4: paged pool holds one physical prefix copy + beats the
        # dense slot footprint; int4 pages fit >= 2.5x bf16's sequences
        "paged_capacity_scales": paged_claims["paged_capacity_scales"],
        "int4_page_capacity_2p5x": paged_claims["int4_page_capacity_2p5x"],
        # ISSUE-5: chunked admission bounds decode-stream stall -- the
        # victim's p99 inter-token gap beats monolithic admission's
        "chunked_prefill_no_stall":
            chunked_claims["chunked_prefill_no_stall"],
        # ISSUE-7: self-speculative decode is bit-identical to plain
        # greedy AND wins ms/tok on >= 1 policy x prefix cell
        "spec_decode_bit_identical":
            spec_claims["spec_decode_bit_identical"],
        "spec_decode_faster": spec_claims["spec_decode_faster"],
        # ISSUE-8: a host-restored prefix is bit-identical to the
        # never-evicted device hit, and beats full re-prefill TTFT on
        # the 2048-token shared prefix
        "offload_bit_identical":
            offload_claims["offload_bit_identical"],
        "offload_restore_faster_than_prefill":
            offload_claims["offload_restore_faster_than_prefill"],
    }

    measured = []
    if not (smoke or quick):
        # measured O(1)-update evidence on CPU (relative only).  Caches
        # come from the policy registry; rotations live inside the int4
        # state.
        cfg, model, params = trained_standin("smol-d128")
        for s_max, prefill_len in ((128, 96), (512, 480)):
            tok = jnp.zeros((2, 1), jnp.int32)
            it = jnp.zeros((2, prefill_len), jnp.int32)
            cq = model.init_cache(2, s_max, policy="int4-srft",
                                  key=jax.random.PRNGKey(7))
            cb = model.init_cache(2, s_max, policy="bf16")
            prefill = jax.jit(model.prefill)
            _, cq = prefill(params, it, cq)
            _, cb = prefill(params, it, cb)
            decode = jax.jit(model.decode_step)
            tq = time_fn(lambda: decode(params, tok, cq), iters=5)
            tb = time_fn(lambda: decode(params, tok, cb), iters=5)
            measured.append({"prefix": prefill_len,
                             "cpu_quant_ms": tq * 1e3,
                             "cpu_bf16_ms": tb * 1e3})
            print(f"  CPU decode_step prefix={prefill_len}: quant "
                  f"{tq*1e3:.1f} ms vs bf16 {tb*1e3:.1f} ms")
        growth_q = measured[1]["cpu_quant_ms"] / measured[0]["cpu_quant_ms"]
        growth_b = measured[1]["cpu_bf16_ms"] / measured[0]["cpu_bf16_ms"]
        claims["o1_updates"] = bool(growth_q < growth_b * 1.5 + 0.5)

    record = {
        "table": "table8_fig1", "rows": rows,
        "engine_measured": engine_rows,
        "batched_measured": batched_rows,
        "paged_measured": paged_rows,
        "chunked_prefill_measured": chunked_rows,
        "spec_decode_measured": spec_rows,
        "prefix_offload_measured": offload_rows,
        "offload_restore_speedup":
            offload_claims["offload_restore_speedup"],
        "offload_tier_depth_ratio":
            offload_claims["offload_tier_depth_ratio"],
        "spec_best_speedup": spec_claims["spec_best_speedup"],
        "int4_page_capacity_multiplier":
            paged_claims["int4_page_capacity_multiplier"],
        "chunked_p99_improvement":
            chunked_claims["chunked_p99_improvement"],
        "fused_geomean_speedup": round(geomean, 3),
        "cpu_measured": measured,
        "smoke": bool(smoke or quick), "claims": claims,
        "notes": (
            "TPU columns are roofline-derived (bandwidth model), the "
            "mechanism the paper itself attributes its win to; "
            "engine_measured rows are CPU wall-clock of the fused "
            "lax.scan decode loop (one dispatch, donated cache) vs the "
            "jit(decode_step)-per-token Python loop, 64 new tokens; "
            "batched_measured rows are continuous-batching tok/s "
            "through the ragged slot cache (BatchEngine), 2x-capacity "
            "mixed-length request queues per batch size; paged_measured "
            "rows are the paged pool's shared-prefix workload (batch 8, "
            "common prompt prefix) with COW refcount evidence and peak "
            "pool bytes vs the dense slot footprint; "
            "chunked_prefill_measured rows are the victim decode "
            "stream's inter-token gap percentiles while a 2K-token "
            "prompt is admitted, chunked vs monolithic prefill; "
            "spec_decode_measured rows are the fused self-speculative "
            "draft-verify engine vs plain fused decode, greedy, on "
            "repetitive prompts (where prompt-lookup drafting pays), "
            "output asserted bit-identical per row before timing; "
            "prefix_offload_measured rows are time-to-first-token of "
            "re-admitting an evicted shared prefix via the host-RAM "
            "int4 page tier (memcpy restore + tail prefill) vs full "
            "re-prefill, restored stream asserted bit-identical to the "
            "never-evicted device-tier hit before timing, plus the "
            "int4-vs-bf16 host-tier depth ratio from exported page "
            "payload bytes."
        ),
    }
    save_record("e2e_decode", record)
    with open(ROOT_RECORD, "w") as f:  # perf trajectory at the repo root
        json.dump(record, f, indent=2, default=float)
    print(f"claims: {claims}")
    print(f"[record] {os.path.abspath(ROOT_RECORD)}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: small prefixes, no kernel "
                    "backend, no trained stand-in")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    record = run(quick=args.quick, smoke=args.smoke)
    if not all(v is not False for v in record["claims"].values()):
        sys.exit(1)
