"""Paper Table 8 / Fig 1 (the central claim): int4 KV decode vs fp16.

The paper measures model.generate wall-clock on Apple M1 unified memory.
This container has no TPU, so the claim is validated the way DESIGN.md §1
states it: decode is HBM-bandwidth-bound, so per-step time is dominated by

    t_step ~ (param_bytes + kv_bytes(prefix)) / HBM_bw + kernel_overhead

and int4 wins iff kv_bytes shrinks by more than the added kernel cost.
Both sides are computed from EXACT byte/FLOP counts of our cache layouts
(the same arithmetic the dry-run validates against compiled HLO), per
prefix length in {256..4096} (Table 8) and per assigned arch at 32K.

A second, measured, component: CPU wall-clock of one decode_step on the
trained d=128 stand-in with quant vs bf16 cache -- ONLY as evidence that
the quant path adds no superlinear work (O(1) updates), not as a latency
claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (fmt_table, save_record, time_fn,
                               trained_standin)
from repro.launch.mesh import HW


def decode_step_model(*, n_layers: int, n_kv: int, d: int, batch: int,
                      prefix: int, group: int, param_bytes: float,
                      window: int = 16) -> dict:
    """Roofline time (s) of one decode step, bf16 vs int4 cache."""
    kv_bf16 = 2 * 2 * n_layers * n_kv * prefix * d * batch
    kv_int4 = 2 * n_layers * n_kv * batch * (
        prefix * (d / 2 + 4 * d / group) + window * 4 * d
    )
    t_bf16 = (param_bytes + kv_bf16) / HW.HBM_BW
    # int4 kernel overhead per step: rotate new K/V (2 d^2 matmul) per
    # layer/head/batch + dequant-in-kernel is part of the attention read
    # (already counted in kv_int4 bytes); query-fold adds one d^2 matmul.
    kernel_flops = 3 * 2.0 * d * d * n_layers * n_kv * batch
    t_int4 = (param_bytes + kv_int4) / HW.HBM_BW \
        + kernel_flops / HW.PEAK_BF16_FLOPS
    return {
        "t_bf16_us": 1e6 * t_bf16, "t_int4_us": 1e6 * t_int4,
        "delta_pct": 100.0 * (t_int4 - t_bf16) / t_bf16,
        "kv_ratio": kv_bf16 / kv_int4,
    }


# Table-8 analogue: a 1.5B-class dense model (Qwen2.5-1.5B-like: 28L,
# d=128, kv=2) and a 1B-class MQA model (Gemma-3-1B-like: 26L, d=256,
# kv=1), single chip, batch 1 -- the paper's laptop regime mapped to one
# v5e chip.
MODELS = [
    ("qwen2.5-1.5b-like", dict(n_layers=28, n_kv=2, d=128, group=32,
                               param_bytes=3.1e9)),
    ("gemma-3-1b-like", dict(n_layers=26, n_kv=1, d=256, group=32,
                             param_bytes=2.0e9)),
]


def run(*, quick: bool = False) -> dict:
    rows = []
    for name, kw in MODELS:
        for prefix in (256, 1024, 2048, 4096, 32768):
            r = decode_step_model(batch=1, prefix=prefix, **kw)
            rows.append({
                "model": name, "prefix": prefix,
                "bf16_us": round(r["t_bf16_us"], 1),
                "int4_us": round(r["t_int4_us"], 1),
                "delta_pct": round(r["delta_pct"], 2),
                "kv_ratio": round(r["kv_ratio"], 2),
            })
    print(fmt_table(rows, ["model", "prefix", "bf16_us", "int4_us",
                           "delta_pct", "kv_ratio"]))

    # measured O(1)-update evidence on CPU (relative only).  Caches come
    # from the policy registry; rotations live inside the int4 state.
    cfg, model, params = trained_standin("smol-d128")
    measured = []
    for s_max, prefill_len in ((128, 96), (512, 480)):
        tok = jnp.zeros((2, 1), jnp.int32)
        it = jnp.zeros((2, prefill_len), jnp.int32)
        cq = model.init_cache(2, s_max, policy="int4-srft",
                              key=jax.random.PRNGKey(7))
        cb = model.init_cache(2, s_max, policy="bf16")
        prefill = jax.jit(model.prefill)
        _, cq = prefill(params, it, cq)
        _, cb = prefill(params, it, cb)
        decode = jax.jit(model.decode_step)
        tq = time_fn(lambda: decode(params, tok, cq), iters=5)
        tb = time_fn(lambda: decode(params, tok, cb), iters=5)
        measured.append({"prefix": prefill_len, "cpu_quant_ms": tq * 1e3,
                         "cpu_bf16_ms": tb * 1e3})
        print(f"  CPU decode_step prefix={prefill_len}: quant "
              f"{tq*1e3:.1f} ms vs bf16 {tb*1e3:.1f} ms")

    # O(1) check: quant-path cost must not grow faster than bf16-path cost
    growth_q = measured[1]["cpu_quant_ms"] / measured[0]["cpu_quant_ms"]
    growth_b = measured[1]["cpu_bf16_ms"] / measured[0]["cpu_bf16_ms"]

    short = [r for r in rows if r["prefix"] <= 4096]
    record = {
        "table": "table8_fig1", "rows": rows, "cpu_measured": measured,
        "claims": {
            # the paper's inversion: negative delta at every tested prefix
            "int4_faster_at_all_prefixes_tpu_model": all(
                r["delta_pct"] < 0 for r in rows),
            "advantage_grows_with_prefix": rows[4]["delta_pct"]
            < rows[0]["delta_pct"],
            "o1_updates": growth_q < growth_b * 1.5 + 0.5,
        },
        "notes": (
            "TPU columns are roofline-derived (bandwidth model), the "
            "mechanism the paper itself attributes its win to; CPU "
            "columns are wall-clock scaling evidence only."
        ),
    }
    save_record("e2e_decode", record)
    print("claims:", record["claims"])
    return record


if __name__ == "__main__":
    run()
