"""Validate a Chrome trace-event JSON export from the flight recorder.

``TraceRecorder.export`` (DESIGN.md §15) promises a file that loads in
Perfetto / chrome://tracing AND carries enough structure to diagnose a
serving stall.  This checker enforces that contract so CI catches a
malformed exporter before a human pastes a broken file into a viewer:

1. **shape** -- ``traceEvents`` is a list of dicts, every event has
   ``name``/``ph``/``ts``/``pid``/``tid``, complete events (``"X"``)
   carry a non-negative ``dur``, instants carry a scope, async
   begin/end events carry an ``id``;
2. **nesting** -- per (pid, tid) track, complete events form a proper
   span tree: sorted by start (ties broken longest-first), every span
   either contains or is disjoint from its neighbours (1 us epsilon
   for clock rounding).  Overlap without containment means the
   exporter emitted garbage timestamps;
3. **request coverage** -- every ``tok.stream`` instant must fall
   inside its request's async ``b``/``e`` window (matched by
   ``args.rid``): the recorder deliberately closes the request track
   only after the final tokens streamed, so a token outside its
   request span is an instrumentation bug.  A missing ``e`` means the
   request was in flight at snapshot time (open window tolerated); a
   missing ``b`` is tolerated only when the ring dropped events or the
   export was windowed (``otherData.dropped > 0`` / ``window_s``);
4. **bound** -- the buffer honored its capacity: recorded events in
   the file never exceed ``otherData.capacity`` (metadata ``M``
   events are synthesized at export and do not count).

Library use: ``problems = check_trace(obj)`` returns a list of
human-readable defects (empty = valid).  CLI use::

    python benchmarks/check_trace.py trace.json [more.json ...]

exits non-zero if any file fails.  server_smoke.py runs this over the
live ``/debug/trace`` snapshot, the SIGUSR1 flight dump and the final
``--trace-out`` file.
"""
from __future__ import annotations

import json
import sys

_EPS_US = 1.0  # clock-rounding tolerance for span containment


def _shape_problems(events) -> list[str]:
    out = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            out.append(f"event[{i}] is not an object: {ev!r}")
            continue
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                out.append(f"event[{i}] ({ev.get('name')!r}) missing {key!r}")
        ph = ev.get("ph")
        if ph != "M" and "ts" not in ev:
            out.append(f"event[{i}] ({ev.get('name')!r}) missing 'ts'")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                out.append(f"span[{i}] {ev.get('name')!r} bad dur: {dur!r}")
        elif ph == "i":
            if ev.get("s") not in ("t", "p", "g"):
                out.append(f"instant[{i}] {ev.get('name')!r} bad scope: "
                           f"{ev.get('s')!r}")
        elif ph in ("b", "e"):
            if "id" not in ev:
                out.append(f"async[{i}] {ev.get('name')!r} missing 'id'")
        elif ph not in ("M",):
            out.append(f"event[{i}] {ev.get('name')!r} unknown ph {ph!r}")
    return out


def _nesting_problems(events) -> list[str]:
    """Complete events on one thread must nest or be disjoint."""
    out = []
    tracks: dict[tuple, list] = {}
    for ev in events:
        if ev.get("ph") == "X" and isinstance(ev.get("dur"), (int, float)):
            key = (ev.get("pid"), ev.get("tid"))
            tracks.setdefault(key, []).append(ev)
    for key, spans in tracks.items():
        spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: list = []  # (end_ts, name)
        for ev in spans:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and stack[-1][0] <= t0 + _EPS_US:
                stack.pop()
            if stack and t1 > stack[-1][0] + _EPS_US:
                out.append(
                    f"tid {key[1]}: span {ev['name']!r} "
                    f"[{t0:.1f}, {t1:.1f}]us overlaps enclosing "
                    f"{stack[-1][1]!r} ending at {stack[-1][0]:.1f}us "
                    f"without nesting"
                )
                continue
            stack.append((t1, ev["name"]))
    return out


def _coverage_problems(events, other) -> list[str]:
    """Every tok.stream instant lies inside its request's b/e window."""
    out = []
    lossy = bool(other.get("dropped")) or other.get("window_s") is not None
    begin: dict = {}
    end: dict = {}
    toks: list = []
    for ev in events:
        ph = ev.get("ph")
        if ph == "b" and ev.get("name") == "request":
            begin.setdefault(ev["id"], ev["ts"])
        elif ph == "e" and ev.get("name") == "request":
            end[ev["id"]] = ev["ts"]
        elif ph == "i" and ev.get("name") == "tok.stream":
            toks.append(ev)
    for ev in toks:
        rid = (ev.get("args") or {}).get("rid")
        if rid is None:
            out.append(f"tok.stream at {ev['ts']:.1f}us has no args.rid")
            continue
        if rid not in begin:
            if lossy:
                continue  # the 'b' fell off the ring / outside the window
            out.append(f"tok.stream rid={rid} has no request 'b' event "
                       f"(and the export is complete: dropped=0, "
                       f"no window)")
            continue
        t0 = begin[rid]
        t1 = end.get(rid, float("inf"))  # in-flight at snapshot time
        if not (t0 - _EPS_US <= ev["ts"] <= t1 + _EPS_US):
            out.append(f"tok.stream rid={rid} at {ev['ts']:.1f}us outside "
                       f"its request span [{t0:.1f}, "
                       f"{'inf' if t1 == float('inf') else f'{t1:.1f}'}]us")
    for rid, t1 in end.items():
        if rid in begin and t1 + _EPS_US < begin[rid]:
            out.append(f"request rid={rid} ends ({t1:.1f}us) before it "
                       f"begins ({begin[rid]:.1f}us)")
    return out


def check_trace(obj) -> list[str]:
    """Return a list of human-readable defects (empty = valid)."""
    if not isinstance(obj, dict):
        return [f"top level is {type(obj).__name__}, expected object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return [f"traceEvents is {type(events).__name__}, expected list"]
    other = obj.get("otherData") or {}
    problems = _shape_problems(events)
    if problems:
        return problems  # structural defects make the rest unreliable
    problems += _nesting_problems(events)
    problems += _coverage_problems(events, other)
    cap = other.get("capacity")
    recorded = sum(1 for e in events if e.get("ph") != "M")
    if isinstance(cap, int) and recorded > cap:
        problems.append(f"{recorded} recorded events exceed the declared "
                        f"ring capacity {cap}")
    return problems


def check_trace_file(path: str) -> list[str]:
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: not readable JSON: {e}"]
    return check_trace(obj)


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_trace.py trace.json [more.json ...]",
              file=sys.stderr)
        return 2
    failed = 0
    for path in argv:
        problems = check_trace_file(path)
        if problems:
            failed += 1
            print(f"[check_trace] FAIL {path}:")
            for p in problems:
                print(f"  - {p}")
        else:
            with open(path) as f:
                n = len(json.load(f)["traceEvents"])
            print(f"[check_trace] OK {path} ({n} events)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
