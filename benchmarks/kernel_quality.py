"""Paper Table 7 + §4.4 Correctness: fused kernel vs Python reference.

Bit-exactness of the Pallas kernel (interpret mode on CPU; compiled on
TPU) against the pure-jnp oracle at every (d, bits, scheme) the paper
ships: d in {64,128,256} x int4/int8 x unscaled / scaled-lambda /
scaled_g32.  The paper reports 99.997-100% agreement with off-by-one
rounding ties; our kernel and oracle share jnp.rint round-half-even, so
we require EXACT agreement (DESIGN.md §1 'assumption changes').

Also reproduces Table 7's quality ladder through the *kernel* path on the
d=128 stand-in: per_token >> g32(no lambda) >> scaled_g32 == Python ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (eval_tokens, fmt_table, hook_ppl, save_record,
                               trained_standin)
from repro.core import calibrate as C
from repro.core.outliers import inject_kv_outliers
from repro.core.transforms import Rotation, make_rotation
from repro.kernels.srft_quant import ops, ref
from repro.models.lm import Rotations, slice_rotation

try:  # benchmarks.ppl_scaling_schemes defines the calibrated-rots helper
    from benchmarks.ppl_scaling_schemes import _calibrated_rots
except ImportError:  # pragma: no cover
    _calibrated_rots = None


def bit_exactness(*, n: int = 2048) -> list[dict]:
    rows = []
    for d in (64, 128, 256):
        for bits in (4, 8):
            for scaled in (False, True):
                key = jax.random.PRNGKey(d + bits)
                rot = make_rotation("srft", key, d)
                if scaled:
                    lam = jnp.exp(
                        0.3 * jax.random.normal(jax.random.PRNGKey(7), (d,))
                    )
                    rot = Rotation(rot.matrix, lam, rot.signs, rot.kind)
                x = 3.0 * jax.random.normal(jax.random.PRNGKey(1), (n, d))
                m = ref.fold_matrix(rot)
                minv = ref.fold_inverse_matrix(rot)
                pk, sk = ops.rotate_quantize(x, rot, group=32, bits=bits)
                pr, sr = ref.srft_quant_ref(x, m, group=32, bits=bits)
                agree = float(np.mean(np.asarray(pk) == np.asarray(pr)))
                scale_rel = float(
                    np.max(np.abs(np.asarray(sk) - np.asarray(sr))
                           / np.maximum(np.abs(np.asarray(sr)), 1e-12))
                )
                # round-trip error through the kernel inverse
                xk = ops.dequantize_rotate(pk, sk, rot, group=32, bits=bits)
                rt_err = float(jnp.abs(
                    xk - ref.srft_dequant_ref(pr, sr, minv, group=32,
                                              bits=bits)
                ).max())
                rows.append({
                    "d": d, "bits": bits,
                    "variant": "scaled_g32" if scaled else "g32",
                    "int_agreement": agree, "scale_rel_err": scale_rel,
                    "kernel_vs_ref_rt": rt_err,
                })
                print(f"  d={d} b={bits} {'scaled' if scaled else 'plain'}: "
                      f"agree={agree:.6f} scale_rel={scale_rel:.2e}")
    return rows


def table7_ladder(*, quick: bool = False) -> dict:
    cfg, model, params = trained_standin("smol-d128")
    # alpha=100: a single K coordinate 100x the rest, the strong version
    # of the paper's Qwen layer-0 probe finding (argmax-entropy 0.17)
    params = inject_kv_outliers(params, head_dim=cfg.head_dim, alpha=100.0,
                                inject_v=False)
    toks = eval_tokens(batch=4 if quick else 8)
    base = hook_ppl(model, params, toks, None, None)
    rots_plain = model.init_rotations(jax.random.PRNGKey(1))
    rots_cal = _calibrated_rots(model, params, toks, rots_plain)

    ladder = [
        ("per_token", rots_plain, dict(bits=4, scheme="per_token", group=32)),
        ("g32_no_lambda", rots_plain, dict(bits=4, scheme="per_group",
                                           group=32)),
        ("scaled_g32", rots_cal, dict(bits=4, scheme="per_channel_group",
                                      group=32)),
    ]
    rows = []
    for name, rots, kw in ladder:
        ppl = hook_ppl(model, params, toks, rots, kw)
        rows.append({"kernel_variant": name, "dppl": round(ppl - base, 4)})
        print(f"  {name:16s} dPPL={ppl - base:+.4f}")
    d = {r["kernel_variant"]: r["dppl"] for r in rows}
    return {
        "rows": rows,
        "claims": {
            "scaled_g32_best": d["scaled_g32"] < d["g32_no_lambda"]
            and d["scaled_g32"] < d["per_token"],
            # the paper's 12.5x is checkpoint-specific (28-layer Qwen with
            # structured multi-channel outliers); what must reproduce is
            # the fused recipe strictly winning with a clear margin
            "reduction_over_per_token_large":
                d["per_token"] > 1.5 * max(d["scaled_g32"], 1e-3),
        },
    }


def run(*, quick: bool = False) -> dict:
    exact = bit_exactness(n=512 if quick else 2048)
    ladder = table7_ladder(quick=quick)
    record = {
        "table": "table7_and_correctness",
        "bit_exactness": exact,
        "quality_ladder": ladder,
        "claims": {
            # int4 must be exactly bit-identical; int8 admits rare
            # off-by-one rounding ties where the kernel's fp32 dot
            # accumulation order differs from the oracle einsum (the
            # paper observes the same tie class, §4.4: 99.997-100%).
            "int4_bit_exact": all(
                r["int_agreement"] == 1.0 for r in exact if r["bits"] == 4),
            "int8_agreement_floor": all(
                r["int_agreement"] >= 0.99999 for r in exact
                if r["bits"] == 8),
            "scales_match": all(r["scale_rel_err"] < 1e-5 for r in exact),
            **ladder["claims"],
        },
    }
    save_record("kernel_quality", record)
    print(fmt_table(exact, ["d", "bits", "variant", "int_agreement",
                            "scale_rel_err"]))
    print("claims:", record["claims"])
    return record


if __name__ == "__main__":
    run()
