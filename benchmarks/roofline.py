"""§Roofline deliverable: per-(arch x shape x mesh) roofline table from the
dry-run artifacts.

Sources, in order of trust:
  * artifacts/roofline/*.json -- depth-extrapolated fits (roofline_fit.py):
    reduced-depth fully-unrolled lowers, linear per-layer fit.  These are
    the CORRECT per-cell costs (XLA cost_analysis counts while-loop bodies
    once, so the raw full-depth artifacts underreport by ~n_layers).
  * artifacts/dryrun/*.json -- raw full-depth compiles; used as the
    compile-success proof (single + multi pod) and as fallback numbers.

For each cell: compute/memory/collective terms in seconds, the dominant
term, MODEL_FLOPS / HLO_FLOPs useful ratio, and one-line bottleneck note.
Also emits the markdown table EXPERIMENTS.md §Roofline embeds.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART, fmt_table, save_record

DRYRUN = os.path.join(ART, "dryrun")
FITTED = os.path.join(ART, "roofline")


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        with open(path) as f:
            cell = json.load(f)
        fit_path = os.path.join(
            FITTED, f"{cell['arch']}__{cell['shape']}__{mesh}.json"
        )
        if os.path.exists(fit_path):
            with open(fit_path) as f:
                fit = json.load(f)
            if fit.get("status") == "ok":
                cell = {**cell, **{k: fit[k] for k in
                                   ("roofline", "model_flops", "fitted")},
                        "method": "depth_fit"}
        cells.append(cell)
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def rows_for(cells: list[dict]) -> list[dict]:
    rows = []
    for c in cells:
        if c.get("status") == "skipped":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "status": "skipped (" + c["reason"][:40] + "...)"})
            continue
        if c.get("status") != "ok":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "status": "ERROR"})
            continue
        r = c["roofline"]
        mf = c.get("model_flops", {})
        dominant = r["bottleneck"].replace("_s", "")
        terms = {k: r[k] for k in ("compute_s", "memory_s", "collective_s")}
        tmax = max(terms.values())
        # roofline fraction: useful model FLOPs per chip-second at peak,
        # over the achievable step time (max of the three terms)
        chips = c.get("chips", 256)
        useful = mf.get("model_flops", 0.0) / chips
        frac = (useful / 197e12) / tmax if tmax > 0 else 0.0
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "status": "ok",
            "compute": _fmt_s(r["compute_s"]),
            "memory": _fmt_s(r["memory_s"]),
            "collective": _fmt_s(r["collective_s"]),
            "bottleneck": dominant,
            "useful_ratio": round(mf.get("useful_ratio") or 0.0, 3),
            "roofline_frac": round(frac, 4),
            "method": c.get("method", "raw"),
        })
    return rows


def run(*, quick: bool = False) -> dict:
    out = {}
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        rows = rows_for(cells)
        out[mesh] = rows
        if mesh == "single":
            print(f"--- {mesh}-pod (16x16 = 256 chips) ---")
            print(fmt_table(
                [r for r in rows if r.get("status") == "ok"],
                ["arch", "shape", "compute", "memory", "collective",
                 "bottleneck", "useful_ratio", "roofline_frac"],
            ))
    ok = [r for r in out["single"] if r.get("status") == "ok"]
    record = {
        "table": "roofline", "cells": out,
        "n_ok_single": len(ok),
        "n_ok_multi": len([r for r in out["multi"]
                           if r.get("status") == "ok"]),
        "claims": {
            "all_single_cells_compile": all(
                r.get("status") in ("ok",) or "skipped" in str(r.get("status"))
                for r in out["single"]),
            "all_multi_cells_compile": all(
                r.get("status") in ("ok",) or "skipped" in str(r.get("status"))
                for r in out["multi"]),
        },
    }
    save_record("roofline", record)
    print("claims:", record["claims"])
    return record


if __name__ == "__main__":
    run()
